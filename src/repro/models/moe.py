"""Mixture-of-Experts layer: top-k router + two execution strategies.

Both strategies share the same capacity-based dispatch (sort-free scatter
into per-expert buffers, tokens over capacity dropped — standard TPU MoE):

``tp_dense``  experts stay replicated on the expert dim; each expert's d_ff
              is sharded over the ``model`` axis.  Dispatch/combine are
              local; pjit inserts the psum for the down-projection.  Right
              for MoEs whose full expert set fits per data shard
              (phi3.5-moe: 16e x 4096 x 6400).

``ep_a2a``    experts sharded over the ``data`` axis via an explicit
              ``shard_map`` all-to-all pair (dispatch + return), d_ff
              additionally sharded over ``model``.  Required for dbrx-132b
              (16e x 6144 x 10752 would be ~16.5 GB/chip dense).

FLOPs for both: 3 * B*S*topk*cf * D * F (capacity-bounded), not E x dense.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..distributed.compat import shard_map
from .layers import ACTS, _dense_init


def init_moe(rng, d: int, f: int, num_experts: int, dtype=jnp.bfloat16):
    k0, k1, k2, k3 = jax.random.split(rng, 4)
    return {
        "router": _dense_init(k0, (d, num_experts), d, jnp.float32),
        "w1": _dense_init(k1, (num_experts, d, f), d, dtype),
        "w3": _dense_init(k2, (num_experts, d, f), d, dtype),
        "w2": _dense_init(k3, (num_experts, f, d), f, dtype),
    }


def spec_moe(strategy: str) -> Dict[str, Any]:
    e = "ep" if strategy == "ep_a2a" else None
    return {
        "router": (None, None),
        "w1": (e, None, "tp"),
        "w3": (e, None, "tp"),
        "w2": (e, "tp", None),
    }


# ---------------------------------------------------------------------------
# Shared dispatch machinery
# ---------------------------------------------------------------------------

def _route(router_w, x, top_k: int):
    """x: [T, D] -> (topk expert ids [T, K], combine weights [T, K])."""
    logits = x.astype(jnp.float32) @ router_w              # [T, E]
    weights, ids = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), top_k)
    weights = weights / jnp.maximum(jnp.sum(weights, -1, keepdims=True), 1e-9)
    return ids, weights, logits


def _dispatch_indices(ids, num_experts: int, capacity: int):
    """Position of each (token, k) assignment within its expert buffer.

    ids: [T, K] -> (pos [T, K], keep [T, K]).  Assignments beyond capacity
    are dropped (standard capacity-factor MoE).
    """
    T, K = ids.shape
    flat = ids.reshape(-1)                                  # [T*K], k-major per token
    onehot = jax.nn.one_hot(flat, num_experts, dtype=jnp.int32)  # [T*K, E]
    pos_in_expert = jnp.cumsum(onehot, axis=0) - 1          # [T*K, E]
    pos = jnp.take_along_axis(pos_in_expert, flat[:, None], axis=1)[:, 0]
    keep = pos < capacity
    return pos.reshape(T, K), keep.reshape(T, K)


def _expert_ffn(w1, w3, w2, buf, act: str):
    """buf: [E, C, D] -> [E, C, D] through per-expert SwiGLU."""
    a = ACTS[act]
    h = a(jnp.einsum("ecd,edf->ecf", buf, w1)) * jnp.einsum("ecd,edf->ecf", buf, w3)
    return jnp.einsum("ecf,efd->ecd", h, w2)


def _moe_tokens(params, x2d, *, top_k, capacity_factor, num_experts, act):
    """Dense (per-shard-local) MoE on a flat token batch [T, D]."""
    T, D = x2d.shape
    capacity = max(int(T * top_k * capacity_factor / num_experts), 1)
    # round capacity to an MXU-friendly multiple
    capacity = ((capacity + 127) // 128) * 128 if capacity >= 128 else capacity
    ids, weights, router_logits = _route(params["router"], x2d, top_k)
    pos, keep = _dispatch_indices(ids, num_experts, capacity)

    buf = jnp.zeros((num_experts, capacity, D), x2d.dtype)
    tok_idx = jnp.broadcast_to(jnp.arange(T)[:, None], ids.shape)
    buf = buf.at[
        jnp.where(keep, ids, 0),
        jnp.where(keep, pos, 0),
    ].add(jnp.where(keep[..., None], x2d[tok_idx], 0))

    out_buf = _expert_ffn(params["w1"], params["w3"], params["w2"], buf, act)

    gathered = out_buf[
        jnp.where(keep, ids, 0), jnp.where(keep, pos, 0)
    ]                                                       # [T, K, D]
    gathered = jnp.where(keep[..., None], gathered, 0.0)
    out = jnp.einsum("tkd,tk->td", gathered.astype(jnp.float32),
                     weights).astype(x2d.dtype)
    return out, router_logits


def _aux_loss(router_logits, ids, num_experts: int):
    """Switch-style load-balance loss: E * sum_e f_e * p_e."""
    probs = jax.nn.softmax(router_logits, axis=-1)          # [T, E]
    frac = jnp.mean(
        jax.nn.one_hot(ids[:, 0], num_experts, dtype=jnp.float32), axis=0)
    return num_experts * jnp.sum(frac * jnp.mean(probs, axis=0))


# ---------------------------------------------------------------------------
# Strategy: tp_dense
# ---------------------------------------------------------------------------

def moe_apply_tp_dense(params, x, *, top_k, capacity_factor, act="silu"):
    """x: [B, S, D] -> ([B, S, D], aux_loss scalar).

    Dispatch is PER BATCH ROW (vmap over B): a flat global-token dispatch
    buffer [E, T_global*k*cf/E, D] is unshardable by the SPMD partitioner
    (no batch dim) and was measured replicated per chip on the 512-way
    mesh (§Perf iteration, phi3.5-moe/train_4k/multi: +70s memory term).
    Row-level capacity uses a mildly larger factor to compensate for the
    finer-grained load-balance pool.
    """
    B, S, D = x.shape
    E = params["w1"].shape[0]
    row_cf = capacity_factor * 1.6

    def per_row(xrow):
        return _moe_tokens(params, xrow, top_k=top_k,
                           capacity_factor=row_cf, num_experts=E, act=act)

    out, router_logits = jax.vmap(per_row)(x)
    ids, _, _ = _route(params["router"], x.reshape(B * S, D), top_k)
    aux = _aux_loss(router_logits.reshape(B * S, E), ids, E)
    return out, aux


# ---------------------------------------------------------------------------
# Strategy: ep_a2a  (shard_map over data x model)
# ---------------------------------------------------------------------------

def moe_apply_ep_a2a(params, x, *, top_k, capacity_factor, act="silu",
                     mesh: Mesh, dp_spec):
    """Expert-parallel MoE: experts sharded over ``data``, a2a dispatch.

    x: [B, S, D] batch-sharded over dp.  Inside shard_map each data shard
    routes its local tokens, builds the full [E, C_loc, D] buffer, and an
    all-to-all rotates expert slabs to their owning shard.  Expert d_ff is
    additionally sharded over ``model``; the down-projection psums over it.
    """
    B, S, D = x.shape
    E = params["w1"].shape[0]
    n_data = mesh.shape["data"]
    assert E % n_data == 0, (E, n_data)
    e_loc = E // n_data

    def body(router_w, w1, w3, w2, xl):
        # xl: [B_loc, S, D]; w1: [E_loc, D, F_loc]
        b_loc = xl.shape[0]
        t = b_loc * S
        x2d = xl.reshape(t, D)
        capacity = max(int(t * top_k * capacity_factor / E), 8)
        ids, weights, router_logits = _route(router_w, x2d, top_k)
        pos, keep = _dispatch_indices(ids, E, capacity)

        buf = jnp.zeros((E, capacity, D), xl.dtype)
        tok_idx = jnp.broadcast_to(jnp.arange(t)[:, None], ids.shape)
        buf = buf.at[
            jnp.where(keep, ids, 0), jnp.where(keep, pos, 0)
        ].add(jnp.where(keep[..., None], x2d[tok_idx], 0))

        # dispatch: [E, C, D] -> [n_data * e_loc, C, D] where my shard now
        # holds slabs destined for MY experts from every source shard.
        recv = jax.lax.all_to_all(
            buf.reshape(n_data, e_loc, capacity, D),
            "data", split_axis=0, concat_axis=0, tiled=False,
        )                                                   # [n_data, e_loc, C, D]
        recv = jnp.swapaxes(recv, 0, 1).reshape(e_loc, n_data * capacity, D)

        a = ACTS[act]
        h = a(jnp.einsum("ecd,edf->ecf", recv, w1)) * \
            jnp.einsum("ecd,edf->ecf", recv, w3)
        out_loc = jnp.einsum("ecf,efd->ecd", h, w2)         # partial over F
        out_loc = jax.lax.psum(out_loc, "model")            # [e_loc, n*C, D]

        # return: reverse the all-to-all
        back = jnp.swapaxes(
            out_loc.reshape(e_loc, n_data, capacity, D), 0, 1)  # [n, e_loc, C, D]
        ret = jax.lax.all_to_all(
            back, "data", split_axis=0, concat_axis=0, tiled=False,
        )                                                   # [n, e_loc, C, D]
        ret = ret.reshape(E, capacity, D)

        gathered = ret[jnp.where(keep, ids, 0), jnp.where(keep, pos, 0)]
        gathered = jnp.where(keep[..., None], gathered, 0.0)
        out = jnp.einsum("tkd,tk->td", gathered.astype(jnp.float32),
                         weights).astype(xl.dtype)
        aux = _aux_loss(router_logits, ids, E)
        return out.reshape(b_loc, S, D), aux

    pod = ("pod",) if "pod" in mesh.axis_names else ()
    out, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(),                                   # router replicated
            P("data", None, "model"),              # w1 [E(ep), D, F(tp)]
            P("data", None, "model"),
            P("data", "model", None),
            dp_spec,                               # x [B(dp), S, D]
        ),
        out_specs=(dp_spec, P()),
    )(params["router"], params["w1"], params["w3"], params["w2"], x)
    return out, aux


# ---------------------------------------------------------------------------
# Strategy: tp_smap  (explicit shard_map TP with combine-before-psum)
# ---------------------------------------------------------------------------

def moe_apply_tp_smap(params, x, *, top_k, capacity_factor, act="silu",
                      mesh: Mesh, dp_spec):
    """TP MoE with the model-axis psum placed AFTER the per-token combine.

    Under plain pjit the down-projection's all-reduce lands on the
    capacity buffer [B, E, C, D] (~6x the token count at cf=2); combining
    expert outputs is linear, so it commutes with the reduction — psum on
    the combined [T, D] moves ~6x fewer bytes (§Perf cell 2 follow-up,
    measured on phi3.5 prefill_32k).  Experts stay replicated on the
    expert dim; d_ff is sharded over ``model``.
    """
    B, S, D = x.shape
    E = params["w1"].shape[0]
    row_cf = capacity_factor * 1.6

    def body(router_w, w1, w3, w2, xl):
        b_loc = xl.shape[0]
        a = ACTS[act]
        t = S
        capacity = max(int(t * top_k * row_cf / E), 8)

        def per_row(xrow):
            ids, weights, router_logits = _route(router_w, xrow, top_k)
            pos, keep = _dispatch_indices(ids, E, capacity)
            buf = jnp.zeros((E, capacity, D), xrow.dtype)
            tok_idx = jnp.broadcast_to(jnp.arange(t)[:, None], ids.shape)
            buf = buf.at[
                jnp.where(keep, ids, 0), jnp.where(keep, pos, 0)
            ].add(jnp.where(keep[..., None], xrow[tok_idx], 0))
            h = a(jnp.einsum("ecd,edf->ecf", buf, w1)) * \
                jnp.einsum("ecd,edf->ecf", buf, w3)
            part = jnp.einsum("ecf,efd->ecd", h, w2)   # PARTIAL over f
            gathered = part[jnp.where(keep, ids, 0),
                            jnp.where(keep, pos, 0)]
            gathered = jnp.where(keep[..., None], gathered, 0.0)
            out = jnp.einsum("tkd,tk->td", gathered.astype(jnp.float32),
                             weights).astype(xrow.dtype)
            return out, router_logits, ids

        out, router_logits, ids = jax.vmap(per_row)(xl)
        out = jax.lax.psum(out, "model")               # combined, not buffer
        aux = _aux_loss(router_logits.reshape(b_loc * S, E),
                        ids.reshape(b_loc * S, top_k), E)
        aux = jax.lax.pmean(aux, "model")
        return out, aux

    pod = P() if "pod" not in mesh.axis_names else P()
    out, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(),                               # router replicated
            P(None, None, "model"),            # w1 [E, D, F(tp)]
            P(None, None, "model"),
            P(None, "model", None),
            dp_spec,                           # x [B(dp), S, D]
        ),
        out_specs=(dp_spec, P()),
    )(params["router"], params["w1"], params["w3"], params["w2"], x)
    return out, aux


def moe_apply(params, x, *, top_k: int, capacity_factor: float,
              strategy: str, act: str = "silu",
              mesh: Optional[Mesh] = None, dp_spec=None):
    if mesh is not None and "model" in mesh.axis_names:
        if strategy == "ep_a2a" and "data" in mesh.axis_names \
                and mesh.shape["data"] > 1:
            return moe_apply_ep_a2a(
                params, x, top_k=top_k, capacity_factor=capacity_factor,
                act=act, mesh=mesh, dp_spec=dp_spec)
        if strategy in ("tp_dense", "tp_smap") and mesh.shape["model"] > 1 \
                and dp_spec is not None:
            return moe_apply_tp_smap(
                params, x, top_k=top_k, capacity_factor=capacity_factor,
                act=act, mesh=mesh, dp_spec=dp_spec)
    return moe_apply_tp_dense(
        params, x, top_k=top_k, capacity_factor=capacity_factor, act=act)
