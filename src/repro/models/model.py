"""The decoder-only LM: embed -> scan(superblocks) [+ tail] -> norm -> head.

Covers 9 of the 10 assigned architectures (whisper-base is enc-dec; see
``whisper.py``).  The repeating ``block_pattern`` is expanded as
``num_layers = R * P + tail``: the R full repetitions are *stacked* (leading
dim R per parameter leaf) and executed with ``jax.lax.scan`` — one compiled
superblock body regardless of depth — while the tail layers run unstacked.

Four entry points, one per serving/training phase:

    forward(params, batch)                     -> logits [B, S, V]   (train)
    prefill(params, batch, s_alloc)            -> (last logits, states)
    extend(params, batch, states, q_offset)    -> (last logits, states)
    decode_step(params, tokens, states, pos)   -> (logits [B, V], states)

``extend`` is the task-cascade primitive: document fraction f_j -> f_i reuse
(the KV prefix for [0, q_offset) is already in ``states``).

VLM (qwen2-vl) inputs may carry ``patch_emb`` [B, S_img, D] — the stubbed
vision frontend — which is prepended to the text token embeddings, and
``positions3`` [B, S, 3] for M-RoPE.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..config import ATTN_FULL, ATTN_LOCAL, ResolvedConfig
from ..distributed.sharding import batch_pspec, constrain
from . import blocks
from .layers import embed_apply, init_embed, init_rmsnorm, lm_head_apply, \
    rmsnorm_apply, spec_embed, spec_rmsnorm
from .runtime import Runtime


def _stack_init(rng, n: int, init_fn):
    """Initialize ``n`` copies of a module, stacked on the leading dim."""
    return jax.vmap(init_fn)(jax.random.split(rng, n))


@dataclass(frozen=True)
class LM:
    rcfg: ResolvedConfig
    rt: Runtime

    # ------------------------------------------------------------------ meta
    @property
    def pattern(self) -> Tuple[str, ...]:
        return self.rcfg.base.block_pattern

    @property
    def n_rep(self) -> int:
        return self.rcfg.base.num_layers // len(self.pattern)

    @property
    def tail_kinds(self) -> Tuple[str, ...]:
        kinds = self.rcfg.base.layer_kinds()
        return kinds[self.n_rep * len(self.pattern):]

    @property
    def dtype(self):
        return jnp.bfloat16 if self.rcfg.base.dtype == "bfloat16" else jnp.float32

    # ---------------------------------------------------------------- params
    def init(self, rng) -> Dict[str, Any]:
        b = self.rcfg.base
        k_emb, k_stage, k_tail = jax.random.split(rng, 3)
        stages = tuple(
            _stack_init(
                jax.random.fold_in(k_stage, pi), self.n_rep,
                functools.partial(
                    blocks.init_block, rcfg=self.rcfg, kind=kind,
                    dtype=self.dtype))
            for pi, kind in enumerate(self.pattern))
        tail = tuple(
            blocks.init_block(jax.random.fold_in(k_tail, ti), self.rcfg,
                              kind, self.dtype)
            for ti, kind in enumerate(self.tail_kinds))
        return {
            "embed": init_embed(k_emb, self.rcfg.padded_vocab, b.d_model,
                                self.dtype),
            "final_norm": init_rmsnorm(b.d_model),
            "stages": stages,
            "tail": tail,
        }

    def param_specs(self) -> Dict[str, Any]:
        stages = tuple(
            jax.tree.map(
                lambda t: (None,) + t,                 # leading R dim replicated
                blocks.spec_block(self.rcfg, kind),
                is_leaf=lambda x: isinstance(x, tuple) and len(x) > 0
                and all(isinstance(a, (str, type(None))) for a in x))
            for kind in self.pattern)
        tail = tuple(blocks.spec_block(self.rcfg, kind)
                     for kind in self.tail_kinds)
        return {
            "embed": spec_embed(),
            "final_norm": spec_rmsnorm(),
            "stages": stages,
            "tail": tail,
        }

    # ---------------------------------------------------------------- states
    def init_states(self, batch: int, s_alloc: int, kv_dtype=None):
        stages = tuple(
            jax.tree.map(
                lambda l: jnp.broadcast_to(l[None], (self.n_rep,) + l.shape),
                blocks.init_block_state(self.rcfg, kind, batch, s_alloc,
                                        self.dtype, kv_dtype=kv_dtype))
            for kind in self.pattern)
        tail = tuple(
            blocks.init_block_state(self.rcfg, kind, batch, s_alloc, self.dtype,
                                    kv_dtype=kv_dtype)
            for kind in self.tail_kinds)
        return {"stages": stages, "tail": tail}

    def state_shapes(self, batch: int, s_alloc: int, kv_dtype=None):
        stages = tuple(
            jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((self.n_rep,) + s.shape, s.dtype),
                blocks.block_state_shape(self.rcfg, kind, batch, s_alloc,
                                         self.dtype, kv_dtype=kv_dtype))
            for kind in self.pattern)
        tail = tuple(
            blocks.block_state_shape(self.rcfg, kind, batch, s_alloc,
                                     self.dtype, kv_dtype=kv_dtype)
            for kind in self.tail_kinds)
        return {"stages": stages, "tail": tail}

    # ------------------------------------------------------- arena state API
    # State pytrees are batched per sequence; the batch axis is 0 for every
    # leaf except scan-stacked "stages" leaves, which carry the repetition
    # dim first (R, B, ...).  ``take_states``/``put_states`` gather/scatter
    # sub-batches along that axis, which is how the serving engine's slot
    # arena packs survivors without per-document Python loops.

    @staticmethod
    def _state_batch_axis(path) -> int:
        key = str(getattr(path[0], "key", getattr(path[0], "idx", path[0])))
        return 1 if key == "stages" else 0

    def take_states(self, states, idx: jnp.ndarray):
        """Gather per-sequence states at ``idx`` [B'] -> batch-B' pytree."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(states)
        out = [jnp.take(leaf, idx, axis=self._state_batch_axis(path))
               for path, leaf in flat]
        return jax.tree_util.tree_unflatten(treedef, out)

    def put_states(self, arena, idx: jnp.ndarray, states):
        """Scatter a batch-B' state pytree into arena slots ``idx``.

        Duplicate slot ids are permitted (used for scratch-slot padding);
        which duplicate wins is unspecified.
        """
        flat_a, treedef = jax.tree_util.tree_flatten_with_path(arena)
        flat_s = jax.tree.leaves(states)
        out = []
        for (path, leaf), sub in zip(flat_a, flat_s):
            if self._state_batch_axis(path) == 0:
                out.append(leaf.at[idx].set(sub.astype(leaf.dtype)))
            else:
                out.append(leaf.at[:, idx].set(sub.astype(leaf.dtype)))
        return jax.tree_util.tree_unflatten(treedef, out)

    @property
    def supports_paged_kv(self) -> bool:
        """True when every layer's serve-state is a full-attention KV
        cache, i.e. the slot arena can be addressed IN PLACE by the paged
        kernels (``slots=`` on ``extend``/``decode_step``) and the
        KV-window helpers below are meaningful.  Sliding-window ring
        caches and recurrent (xLSTM/RG-LRU) states still require the
        gather/scatter path."""
        return all(k == ATTN_FULL for k in self.rcfg.base.layer_kinds())

    def _kv_window_idx(self, slots: jnp.ndarray, start: jnp.ndarray,
                       length: int):
        win = start[:, None] + jnp.arange(length, dtype=jnp.int32)[None]
        return slots[:, None], win                       # [B, 1], [B, L]

    def take_kv_window(self, states, slots: jnp.ndarray,
                       start: jnp.ndarray, length: int):
        """Gather cache rows [start[b], start[b]+length) of every KV leaf
        at arena rows ``slots`` -> a tiny [B, length, KV, Dh]-per-leaf
        pytree.  With ``put_kv_window`` this is the paged op-suffix UNDO
        LOG: the serving engine snapshots the ``length`` cache positions
        an operation suffix will dirty, decodes in place, then restores —
        O(B * op_len) bytes instead of the full [B, S] row copy.  Only
        valid for ``supports_paged_kv`` models (every leaf is a KV cache
        whose sequence axis follows the batch axis)."""
        si, win = self._kv_window_idx(slots, start, length)
        flat, treedef = jax.tree_util.tree_flatten_with_path(states)
        out = [leaf[si, win] if self._state_batch_axis(path) == 0
               else leaf[:, si, win]
               for path, leaf in flat]
        return jax.tree_util.tree_unflatten(treedef, out)

    def put_kv_window(self, states, slots: jnp.ndarray,
                      start: jnp.ndarray, length: int, window):
        """Scatter a ``take_kv_window`` snapshot back into the arena.
        Duplicate rows (scratch-slot padding) are permitted; which
        duplicate wins is unspecified — scratch contents are never read
        unmasked."""
        si, win = self._kv_window_idx(slots, start, length)
        flat, treedef = jax.tree_util.tree_flatten_with_path(states)
        subs = jax.tree.leaves(window)
        out = []
        for (path, leaf), sub in zip(flat, subs):
            if self._state_batch_axis(path) == 0:
                out.append(leaf.at[si, win].set(sub))
            else:
                out.append(leaf.at[:, si, win].set(sub))
        return jax.tree_util.tree_unflatten(treedef, out)

    def state_specs(self, *, batch_sharded: bool, seq_sharded: bool):
        def with_lead(tree):
            return jax.tree.map(
                lambda t: (None,) + t, tree,
                is_leaf=lambda x: isinstance(x, tuple) and len(x) > 0
                and all(isinstance(a, (str, type(None))) for a in x))
        stages = tuple(
            with_lead(blocks.spec_block_state(
                self.rcfg, kind, batch_sharded=batch_sharded,
                seq_sharded=seq_sharded))
            for kind in self.pattern)
        tail = tuple(
            blocks.spec_block_state(self.rcfg, kind,
                                    batch_sharded=batch_sharded,
                                    seq_sharded=seq_sharded)
            for kind in self.tail_kinds)
        return {"stages": stages, "tail": tail}

    # ----------------------------------------------------------------- embed
    def _dp_spec(self):
        if self.rt.mesh is None:
            return None
        return batch_pspec(self.rt.mesh, None, None)

    def _constrain_act(self, x):
        if self.rt.mesh is None:
            return x
        mesh = self.rt.mesh
        seq = "model" if (self.rt.sp_activations
                          and x.shape[1] % mesh.shape["model"] == 0) else None
        dp = batch_pspec(mesh)[0] if x.shape[0] % _dp_size(mesh) == 0 else None
        return constrain(x, mesh, P(dp, seq, None))

    def embed_inputs(self, params, batch: Dict[str, jnp.ndarray]):
        b = self.rcfg.base
        x = embed_apply(params["embed"], batch["tokens"]).astype(self.dtype)
        if b.frontend_stub == "vision_patches" and "patch_emb" in batch:
            x = jnp.concatenate(
                [batch["patch_emb"].astype(self.dtype), x], axis=1)
        if b.frontend_stub == "audio_frames" and "frame_emb" in batch:
            x = jnp.concatenate(
                [batch["frame_emb"].astype(self.dtype), x], axis=1)
        if getattr(b, "embed_scale", False):
            x = x * jnp.asarray(b.d_model ** 0.5, self.dtype)
        return x

    # ------------------------------------------------------------------ core
    def _run_blocks(self, params, x, *, mode, states=None, cache_len=None,
                    q_offset=0, kv_len=None, slots=None, block_tables=None,
                    positions=None, positions3=None):
        rcfg, rt = self.rcfg, self.rt
        dp_spec = self._dp_spec()
        pattern = self.pattern
        aux0 = jnp.zeros((), jnp.float32)

        def superblock(carry, xs):
            x, aux = carry
            stage_params, stage_states = xs
            new_states = []
            for pi, kind in enumerate(pattern):
                st = None if stage_states is None else stage_states[pi]
                x, ns, a = blocks.block_apply(
                    stage_params[pi], x, kind=kind, rcfg=rcfg, rt=rt,
                    mode=mode, state=st, cache_len=cache_len,
                    q_offset=q_offset, kv_len=kv_len, slots=slots,
                    block_tables=block_tables,
                    positions=positions, positions3=positions3,
                    dp_spec=dp_spec)
                x = self._constrain_act(x)
                new_states.append(ns)
                aux = aux + a
            return (x, aux), (tuple(new_states) if mode != "train" else 0)

        if mode == "train" and rt.remat:
            superblock = jax.checkpoint(
                superblock,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

        if self.n_rep > 0 and rt.unroll_layers:
            # Python-loop unroll (dry-run cost-extrapolation compiles)
            carry = (x, aux0)
            new_list = []
            for r in range(self.n_rep):
                sp = jax.tree.map(lambda l: l[r], params["stages"])
                st = (jax.tree.map(lambda l: l[r], states["stages"])
                      if states is not None else None)
                carry, ns = superblock(carry, (sp, st))
                new_list.append(ns)
            x, aux = carry
            if states is not None:
                new_stage_states = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *new_list)
            else:
                new_stage_states = ()
        elif self.n_rep > 0:
            st_stages = states["stages"] if states is not None else tuple(
                None for _ in pattern)
            if states is None:
                # scan still needs xs leaves of leading dim R; use params only
                (x, aux), _ = jax.lax.scan(
                    lambda c, sp: superblock(c, (sp, None)),
                    (x, aux0), params["stages"])
            else:
                (x, aux), new_stage_states = jax.lax.scan(
                    superblock, (x, aux0), (params["stages"], st_stages))
        else:
            aux = aux0
            new_stage_states = ()

        new_tail = []
        for ti, kind in enumerate(self.tail_kinds):
            st = None if states is None else states["tail"][ti]
            x, ns, a = blocks.block_apply(
                params["tail"][ti], x, kind=kind, rcfg=rcfg, rt=rt,
                mode=mode, state=st, cache_len=cache_len, q_offset=q_offset,
                kv_len=kv_len, slots=slots, block_tables=block_tables,
                positions=positions, positions3=positions3, dp_spec=dp_spec)
            x = self._constrain_act(x)
            new_tail.append(ns)
            aux = aux + a

        if mode == "train":
            return x, None, aux
        if states is None:
            new_stage_states = tuple(
                None for _ in pattern) if self.n_rep else ()
        return x, {"stages": new_stage_states, "tail": tuple(new_tail)}, aux

    # ------------------------------------------------------------ entry pts
    def forward(self, params, batch: Dict[str, jnp.ndarray]):
        """Training/eval forward -> (logits [B, S, V], moe aux)."""
        x = self.embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = batch.get(
            "positions", jnp.broadcast_to(jnp.arange(S)[None], (B, S)))
        x, _, aux = self._run_blocks(
            params, x, mode="train", positions=positions,
            positions3=batch.get("positions3"))
        x = rmsnorm_apply(params["final_norm"], x, self.rcfg.base.norm_eps)
        logits = lm_head_apply(params["embed"], x, self.rcfg.base.logit_softcap)
        return logits, aux

    def loss(self, params, batch: Dict[str, jnp.ndarray]):
        """Mean next-token xent (+ MoE aux).  ``labels`` [B, S_total]."""
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        V = logits.shape[-1]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        onehot = jax.nn.one_hot(labels, V, dtype=jnp.float32)
        tok_ll = jnp.sum(onehot * logp, axis=-1)
        mask = batch.get("loss_mask", jnp.ones_like(tok_ll))
        loss = -jnp.sum(tok_ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return loss + 0.01 * aux

    def prefill(self, params, batch: Dict[str, jnp.ndarray], *,
                s_alloc: Optional[int] = None):
        """Full prompt pass -> (last-token logits [B, V], states)."""
        x = self.embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = batch.get(
            "positions", jnp.broadcast_to(jnp.arange(S)[None], (B, S)))
        states = self.init_states(B, s_alloc or S) if s_alloc else None
        if states is not None:
            # prefill writes into preallocated caches via extend at offset 0
            x, new_states, _ = self._run_blocks(
                params, x, mode="extend", states=states, q_offset=0,
                positions=positions, positions3=batch.get("positions3"),
                cache_len=jnp.zeros((B,), jnp.int32))
        else:
            x, new_states, _ = self._run_blocks(
                params, x, mode="prefill", positions=positions,
                positions3=batch.get("positions3"))
        x = rmsnorm_apply(params["final_norm"], x[:, -1:],
                          self.rcfg.base.norm_eps)
        logits = lm_head_apply(params["embed"], x,
                               self.rcfg.base.logit_softcap)[:, 0]
        return logits, new_states

    def extend(self, params, batch: Dict[str, jnp.ndarray], states,
               q_offset: int, kv_len: Optional[jnp.ndarray] = None,
               slots: Optional[jnp.ndarray] = None,
               block_tables: Optional[jnp.ndarray] = None):
        """Cascade fraction-extension: new tokens at [q_offset, q_offset+S).

        ``kv_len`` [B] is the TRUE (unpadded) sequence length including this
        chunk: keys at positions >= kv_len[b] are bucket PAD and masked for
        every query, so padded rows cannot attend to PAD KV written by
        earlier chunks (the serving engine passes per-document true lengths;
        None keeps the unmasked fast path for exact-length callers).

        ``slots`` [B] switches to PAGED mode: ``states`` is the slot arena
        (batch dim = arena rows) and row ``slots[b]`` is extended in place
        — the chunk's KV scatters into the arena and attention reads it
        through the paged kernels, so no per-launch row gather/scatter is
        needed.  Requires ``supports_paged_kv``.

        ``block_tables`` [B, nblocks] (paged mode only) redirects READS:
        cache block ``j`` of sequence ``b`` is fetched from arena row
        ``block_tables[b, j]`` instead of ``slots[b]`` — the prefix-sharing
        indirection.  Writes still land in row ``slots[b]``.
        """
        x = self.embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = q_offset + jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x, new_states, _ = self._run_blocks(
            params, x, mode="extend", states=states, q_offset=q_offset,
            kv_len=kv_len, slots=slots, block_tables=block_tables,
            positions=positions,
            positions3=batch.get("positions3"),
            cache_len=jnp.full((B,), q_offset, jnp.int32))
        x = rmsnorm_apply(params["final_norm"], x[:, -1:],
                          self.rcfg.base.norm_eps)
        logits = lm_head_apply(params["embed"], x,
                               self.rcfg.base.logit_softcap)[:, 0]
        return logits, new_states

    def decode_step(self, params, tokens: jnp.ndarray, states,
                    pos: jnp.ndarray, slots: Optional[jnp.ndarray] = None,
                    block_tables: Optional[jnp.ndarray] = None):
        """One decode step. tokens [B], pos [B] -> (logits [B, V], states).

        ``slots`` [B] switches to PAGED mode: ``states`` is the slot arena
        and the step reads/writes row ``slots[b]`` in place (the token's
        KV lands at position ``pos[b]`` of that row; callers that must not
        dirty the row — the serving op suffix — bracket the steps with
        ``take_kv_window``/``put_kv_window``).  ``block_tables``
        [B, nblocks] redirects cache READS per block (prefix sharing);
        the written token still lands in ``slots[b]``."""
        x = embed_apply(params["embed"], tokens[:, None]).astype(self.dtype)
        if getattr(self.rcfg.base, "embed_scale", False):
            x = x * jnp.asarray(self.rcfg.base.d_model ** 0.5, self.dtype)
        positions = pos[:, None]
        positions3 = None
        if self.rcfg.base.mrope_sections is not None:
            positions3 = jnp.broadcast_to(
                pos[:, None, None], (pos.shape[0], 1, 3)).astype(jnp.int32)
        x, new_states, _ = self._run_blocks(
            params, x, mode="decode", states=states, cache_len=pos,
            slots=slots, block_tables=block_tables, positions=positions,
            positions3=positions3)
        x = rmsnorm_apply(params["final_norm"], x, self.rcfg.base.norm_eps)
        logits = lm_head_apply(params["embed"], x,
                               self.rcfg.base.logit_softcap)[:, 0]
        return logits, new_states


def _dp_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n
