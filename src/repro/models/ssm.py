"""Recurrent sequence mixers: xLSTM (mLSTM + sLSTM) and RG-LRU (Griffin).

TPU adaptation notes (DESIGN.md §2.1): the reference CUDA kernels for these
blocks are replaced with MXU-friendly formulations —

* **mLSTM** runs in *chunkwise-parallel* form: within a chunk of L tokens the
  Gram matrix / decay matrix math is dense [L, L] einsums (MXU work); across
  chunks a short ``lax.scan`` carries the (C, n, m) matrix-memory state.
  This is the TPU analogue of the xLSTM "chunkwise" CUDA kernel, validated
  against the sequential recurrence in tests.
* **sLSTM** has a true nonlinear recurrence (h_{t-1} enters the gate
  pre-activations), so it cannot be parallelized over time; we scan with a
  per-head block-diagonal recurrent matrix.  This sequential scan is a
  property of the architecture, not the port.
* **RG-LRU** is a gated *linear* recurrence -> ``jax.lax.associative_scan``
  (log-depth parallel scan), plus a width-4 depthwise conv with carried
  state for decode.

All mixers expose (train/full, step) entry points with explicit state
pytrees so the serving engine can stream documents through cascades.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import _dense_init

LOG_EPS = -30.0


# ===========================================================================
# mLSTM
# ===========================================================================

def init_mlstm(rng, d: int, heads: int, dtype=jnp.bfloat16):
    ks = jax.random.split(rng, 8)
    return {
        "wq": _dense_init(ks[0], (d, d), d, dtype),
        "wk": _dense_init(ks[1], (d, d), d, dtype),
        "wv": _dense_init(ks[2], (d, d), d, dtype),
        "wi": _dense_init(ks[3], (d, heads), d, jnp.float32),
        "wf": _dense_init(ks[4], (d, heads), d, jnp.float32),
        "wo": _dense_init(ks[5], (d, d), d, dtype),
        "wz": _dense_init(ks[6], (d, d), d, dtype),     # gate branch
        "wd": _dense_init(ks[7], (d, d), d, dtype),     # down proj
        "bf": jnp.ones((heads,), jnp.float32) * 2.0,    # forget bias -> long memory
        "bi": jnp.zeros((heads,), jnp.float32),
    }


def spec_mlstm():
    return {
        "wq": (None, "tp"), "wk": (None, "tp"), "wv": (None, "tp"),
        "wi": (None, None), "wf": (None, None),
        "wo": (None, "tp"), "wz": (None, "tp"), "wd": ("tp", None),
        "bf": (None,), "bi": (None,),
    }


def init_mlstm_state(batch: int, heads: int, dh: int, dtype=jnp.float32):
    return {
        "C": jnp.zeros((batch, heads, dh, dh), dtype),   # matrix memory [dv, dk]
        "n": jnp.zeros((batch, heads, dh), dtype),
        "m": jnp.full((batch, heads), LOG_EPS, dtype),
    }


def mlstm_state_shape(batch: int, heads: int, dh: int):
    return {
        "C": jax.ShapeDtypeStruct((batch, heads, dh, dh), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, heads, dh), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, heads), jnp.float32),
    }


def spec_mlstm_state():
    # dv (C dim 2) sharded over model: heads (4) < tp, so shard inner dim
    return {"C": ("dp", None, "tp", None), "n": ("dp", None, "tp"),
            "m": ("dp", None)}


def _mlstm_gates(p, x):
    """x: [B, T, D] -> (q,k,v [B,T,H,dh], li/lf [B,T,H] log gates, o,z)."""
    B, T, D = x.shape
    H = p["wi"].shape[1]
    dh = D // H
    q = (x @ p["wq"]).reshape(B, T, H, dh)
    k = (x @ p["wk"]).reshape(B, T, H, dh) * (dh ** -0.5)
    v = (x @ p["wv"]).reshape(B, T, H, dh)
    li = x.astype(jnp.float32) @ p["wi"] + p["bi"]          # input gate pre-act
    lf = jax.nn.log_sigmoid(x.astype(jnp.float32) @ p["wf"] + p["bf"])
    o = jax.nn.sigmoid(x @ p["wo"])
    z = jax.nn.silu(x @ p["wz"])
    return q, k, v, li, lf, o, z


def mlstm_chunk(q, k, v, li, lf, state, chunk: int):
    """Chunkwise-parallel mLSTM core.

    q/k/v: [B, T, H, dh]; li/lf: [B, T, H]; state from init_mlstm_state.
    Returns (h [B, T, H, dh], new state).  T must be a multiple of chunk.
    """
    B, T, H, dh = q.shape
    L = min(chunk, T)
    assert T % L == 0, (T, L)
    nc = T // L

    def resh(x):
        return jnp.moveaxis(
            x.reshape(B, nc, L, H, -1).squeeze(-1)
            if x.ndim == 3 else x.reshape(B, nc, L, H, dh), 1, 0)

    qc = jnp.moveaxis(q.reshape(B, nc, L, H, dh), 1, 0)     # [nc,B,L,H,dh]
    kc = jnp.moveaxis(k.reshape(B, nc, L, H, dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nc, L, H, dh), 1, 0)
    lic = jnp.moveaxis(li.reshape(B, nc, L, H), 1, 0)       # [nc,B,L,H]
    lfc = jnp.moveaxis(lf.reshape(B, nc, L, H), 1, 0)

    tri = jnp.tril(jnp.ones((L, L), bool))                  # j <= i

    def step(carry, xs):
        C, n, m = carry                                     # [B,H,dh,dh],[B,H,dh],[B,H]
        qb, kb, vb, lib, lfb = xs
        qf = qb.astype(jnp.float32)
        kf = kb.astype(jnp.float32)
        vf = vb.astype(jnp.float32)
        a = jnp.cumsum(lfb, axis=1)                         # [B,L,H] inclusive
        A = a[:, -1]                                        # [B,H]
        # intra-chunk log weights S[b,h,i,j] = a_i - a_j + li_j  (j <= i)
        S = (a[:, :, None, :] - a[:, None, :, :]
             + lib[:, None, :, :])                          # [B,i,j,H]
        S = jnp.moveaxis(S, 3, 1)                           # [B,H,i,j]
        S = jnp.where(tri[None, None], S, -jnp.inf)
        inter = m[:, :, None] + jnp.moveaxis(a, 2, 1)       # [B,H,i]
        m_i = jnp.maximum(jnp.max(S, axis=-1), inter)       # [B,H,i]
        m_i = jnp.maximum(m_i, LOG_EPS)
        w_intra = jnp.exp(S - m_i[..., None])               # [B,H,i,j]
        w_inter = jnp.exp(inter - m_i)                      # [B,H,i]
        gram = jnp.einsum("blhd,bjhd->bhlj", qf, kf)        # [B,H,i,j]
        num = jnp.einsum("bhij,bjhd->bihd", w_intra * gram, vf) \
            + jnp.einsum("bhi,bhde,bihe->bihd", w_inter, C, qf)
        nvec = jnp.einsum("bhij,bjhd->bihd", w_intra, kf) \
            + w_inter[..., None].transpose(0, 2, 1, 3) * n[:, None]
        qn = jnp.einsum("bihd,bihd->bih", nvec, qf)         # [B,i,H]
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_i).transpose(0, 2, 1))
        h = num / denom[..., None]                          # [B,L,H,dh]

        # end-of-chunk state
        wj = (A[:, None] - a) + lib                         # [B,L,H] log weight of input j
        m_new = jnp.maximum(m + A, jnp.max(wj, axis=1))     # [B,H]
        m_new = jnp.maximum(m_new, LOG_EPS)
        carryw = jnp.exp(m + A - m_new)                     # [B,H]
        inpw = jnp.exp(wj - m_new[:, None])                 # [B,L,H]
        C_new = carryw[..., None, None] * C + \
            jnp.einsum("blh,blhd,blhe->bhde", inpw, vf, kf)
        n_new = carryw[..., None] * n + \
            jnp.einsum("blh,blhd->bhd", inpw, kf)
        return (C_new, n_new, m_new), h

    (C, n, m), hs = jax.lax.scan(
        step, (state["C"], state["n"], state["m"]), (qc, kc, vc, lic, lfc))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, T, H, dh)
    return h, {"C": C, "n": n, "m": m}


def mlstm_recurrent_ref(q, k, v, li, lf, state):
    """Sequential recurrence — the correctness oracle for mlstm_chunk."""
    B, T, H, dh = q.shape

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, lit, lft = xs                           # [B,H,dh],[B,H]
        m_new = jnp.maximum(lft + m, lit)
        m_new = jnp.maximum(m_new, LOG_EPS)
        fw = jnp.exp(lft + m - m_new)
        iw = jnp.exp(lit - m_new)
        C = fw[..., None, None] * C + iw[..., None, None] * \
            jnp.einsum("bhd,bhe->bhde", vt, kt)
        n = fw[..., None] * n + iw[..., None] * kt
        qn = jnp.einsum("bhd,bhd->bh", n, qt)
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
        h = jnp.einsum("bhde,bhe->bhd", C, qt) / denom[..., None]
        return (C, n, m_new), h

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in
               (q.astype(jnp.float32), k.astype(jnp.float32),
                v.astype(jnp.float32), li, lf))
    (C, n, m), hs = jax.lax.scan(step, (state["C"], state["n"], state["m"]), xs)
    return jnp.moveaxis(hs, 0, 1), {"C": C, "n": n, "m": m}


def mlstm_apply(p, x, *, state=None, mode: str = "full", chunk: int = 256,
                heads: int = 4):
    """Full mLSTM block: gates + core + output gating + down-proj.

    mode "full": x [B, T, D]; mode "step": x [B, 1, D] with state.
    Returns (y [B, T, D], new_state).
    """
    B, T, D = x.shape
    if state is None:
        state = init_mlstm_state(B, heads, D // heads)
    q, k, v, li, lf, o, z = _mlstm_gates(p, x)
    if mode == "step":
        h, new_state = mlstm_recurrent_ref(q, k, v, li, lf, state)
    else:
        h, new_state = mlstm_chunk(q, k, v, li, lf, state, chunk)
    h = h.reshape(B, T, D).astype(x.dtype) * o
    y = (h * z) @ p["wd"]
    return y, new_state


# ===========================================================================
# sLSTM
# ===========================================================================

def init_slstm(rng, d: int, heads: int, dtype=jnp.bfloat16):
    ks = jax.random.split(rng, 4)
    dh = d // heads
    w = _dense_init(ks[0], (d, 4 * d), d, dtype)
    r = (jax.random.normal(ks[1], (4, heads, dh, dh), jnp.float32)
         * (1.0 / math.sqrt(dh))).astype(jnp.float32)
    return {
        "w": w,                                  # x -> (z,i,f,o) pre-acts
        "r": r,                                  # recurrent block-diag per head
        "b": jnp.concatenate([
            jnp.zeros((d,), jnp.float32),
            jnp.zeros((d,), jnp.float32),
            jnp.ones((d,), jnp.float32) * 2.0,   # forget bias
            jnp.zeros((d,), jnp.float32)]),
        "wo": _dense_init(ks[2], (d, d), d, dtype),
        "wd": _dense_init(ks[3], (d, d), d, dtype),
    }


def spec_slstm():
    return {"w": (None, "tp"), "r": (None, None, None, None), "b": (None,),
            "wo": (None, "tp"), "wd": ("tp", None)}


def init_slstm_state(batch: int, d: int, dtype=jnp.float32):
    return {
        "c": jnp.zeros((batch, d), dtype),
        "n": jnp.full((batch, d), 1e-6, dtype),
        "h": jnp.zeros((batch, d), dtype),
        "m": jnp.full((batch, d), LOG_EPS, dtype),
    }


def slstm_state_shape(batch: int, d: int):
    return {k: jax.ShapeDtypeStruct((batch, d), jnp.float32)
            for k in ("c", "n", "h", "m")}


def spec_slstm_state():
    return {k: ("dp", "tp") for k in ("c", "n", "h", "m")}


def slstm_apply(p, x, *, state=None, heads: int = 4, mode: str = "full"):
    """sLSTM block. x: [B, T, D]. Sequential over T (true recurrence)."""
    B, T, D = x.shape
    dh = D // heads
    if state is None:
        state = init_slstm_state(B, D)
    pre = (x @ p["w"]).astype(jnp.float32) + p["b"]         # [B, T, 4D]
    pre = jnp.moveaxis(pre.reshape(B, T, 4, D), 1, 0)       # [T, B, 4, D]

    r = p["r"]                                              # [4, H, dh, dh]

    def step(carry, xs):
        c, n, h, m = carry
        # recurrent contribution: h grouped per head
        hh = h.reshape(B, heads, dh)
        rec = jnp.einsum("bhd,ghde->bghe", hh, r).reshape(B, 4, D)
        zp, ip, fp, op = [xs[:, g] + rec[:, g] for g in range(4)]
        z = jnp.tanh(zp)
        o = jax.nn.sigmoid(op)
        lf = jax.nn.log_sigmoid(fp)
        m_new = jnp.maximum(lf + m, ip)
        m_new = jnp.maximum(m_new, LOG_EPS)
        fw = jnp.exp(lf + m - m_new)
        iw = jnp.exp(ip - m_new)
        c = fw * c + iw * z
        n = fw * n + iw
        h_new = o * c / jnp.maximum(n, 1e-6)
        return (c, n, h_new, m_new), h_new

    (c, n, h, m), hs = jax.lax.scan(
        step, (state["c"], state["n"], state["h"], state["m"]), pre)
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)              # [B, T, D]
    y = jax.nn.sigmoid(x @ p["wo"]) * y
    y = y @ p["wd"]
    return y, {"c": c, "n": n, "h": h, "m": m}


# ===========================================================================
# RG-LRU (Griffin / RecurrentGemma recurrent block)
# ===========================================================================

RGLRU_C = 8.0
CONV_WIDTH = 4


def init_rglru(rng, d: int, d_rnn: int, dtype=jnp.bfloat16):
    ks = jax.random.split(rng, 6)
    # Lambda init so a = exp(-8*softplus(L)*r) spans slow/fast decay
    u = jax.random.uniform(ks[4], (d_rnn,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / RGLRU_C))         # softplus^-1
    return {
        "w_in": _dense_init(ks[0], (d, d_rnn), d, dtype),
        "w_gate": _dense_init(ks[1], (d, d_rnn), d, dtype),
        "conv": (jax.random.normal(ks[2], (CONV_WIDTH, d_rnn), jnp.float32)
                 * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_rnn,), dtype),
        "w_r": _dense_init(ks[3], (d_rnn, d_rnn), d_rnn, dtype),
        "w_i": _dense_init(ks[5], (d_rnn, d_rnn), d_rnn, dtype),
        "b_r": jnp.zeros((d_rnn,), jnp.float32),
        "b_i": jnp.zeros((d_rnn,), jnp.float32),
        "lam": lam,
        "w_out": _dense_init(jax.random.fold_in(rng, 7), (d_rnn, d), d_rnn, dtype),
    }


def spec_rglru():
    return {
        "w_in": (None, "tp"), "w_gate": (None, "tp"),
        "conv": (None, "tp"), "conv_b": ("tp",),
        "w_r": (None, "tp"), "w_i": (None, "tp"),
        "b_r": ("tp",), "b_i": ("tp",), "lam": ("tp",),
        "w_out": ("tp", None),
    }


def init_rglru_state(batch: int, d_rnn: int, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, d_rnn), dtype),
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, d_rnn), dtype),
    }


def rglru_state_shape(batch: int, d_rnn: int):
    return {
        "h": jax.ShapeDtypeStruct((batch, d_rnn), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, CONV_WIDTH - 1, d_rnn), jnp.float32),
    }


def spec_rglru_state():
    return {"h": ("dp", "tp"), "conv": ("dp", None, "tp")}


def _causal_conv(xi, conv_w, conv_b, conv_state):
    """Depthwise causal conv, width 4. xi: [B, T, d_rnn]."""
    B, T, dr = xi.shape
    hist = jnp.concatenate([conv_state, xi.astype(jnp.float32)], axis=1)
    out = jnp.zeros((B, T, dr), jnp.float32)
    for w in range(CONV_WIDTH):
        out = out + hist[:, w:w + T] * conv_w[w].astype(jnp.float32)
    new_state = hist[:, -(CONV_WIDTH - 1):]
    return out + conv_b.astype(jnp.float32), new_state


def rglru_apply(p, x, *, state=None, mode: str = "full"):
    """Griffin recurrent block. x: [B, T, D] -> ([B, T, D], state)."""
    B, T, D = x.shape
    dr = p["w_in"].shape[1]
    if state is None:
        state = init_rglru_state(B, dr)
    gate = jax.nn.gelu((x @ p["w_gate"]).astype(jnp.float32))
    xi = x @ p["w_in"]
    xi, conv_state = _causal_conv(xi, p["conv"], p["conv_b"], state["conv"])

    r = jax.nn.sigmoid(xi @ p["w_r"].astype(jnp.float32) + p["b_r"])
    i = jax.nn.sigmoid(xi @ p["w_i"].astype(jnp.float32) + p["b_i"])
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * r         # [B, T, dr]
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xi)

    if T == 1:
        h = a[:, 0] * state["h"] + gated[:, 0]
        hs = h[:, None]
    else:
        # associative scan over time: pairs (a_t, b_t); include carry by
        # folding the initial state into the first step.
        b0 = gated.at[:, 0].add(a[:, 0] * state["h"])

        def combine(l, r_):
            al, bl = l
            ar, br = r_
            return al * ar, ar * bl + br

        _, hs = jax.lax.associative_scan(combine, (a, b0), axis=1)
        h = hs[:, -1]

    y = (hs * gate).astype(x.dtype) @ p["w_out"]
    return y, {"h": h, "conv": conv_state}
