"""Block layer: sequence mixer (+ optional FFN) with pre-norms.

A block is one transformer-ish layer of a given *kind* (config.py constants):
attention (full / sliding-window / bidirectional), mLSTM, sLSTM, or RG-LRU.
Every block exposes the same functional surface —

    init_block / spec_block                   parameters
    init_block_state / block_state_shape /    decode-time state (KV cache or
        spec_block_state                      recurrent state)
    block_apply(mode=train|prefill|extend|decode)

so the model can scan over heterogeneous superblock patterns uniformly.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..config import (ATTN_FULL, ATTN_LOCAL, ENC_ATTN, MLSTM, RGLRU, SLSTM,
                      ResolvedConfig)
from . import ssm
from .attention import (attention_apply, init_attention, init_kv_cache,
                        kv_cache_shape, spec_attention, spec_kv_cache)
from .layers import (init_mlp, init_rmsnorm, mlp_apply, rmsnorm_apply,
                     spec_mlp, spec_rmsnorm)
from .moe import init_moe, moe_apply, spec_moe
from .runtime import Runtime

_ATTN_KINDS = (ATTN_FULL, ATTN_LOCAL, ENC_ATTN)


def _has_ffn(rcfg: ResolvedConfig) -> bool:
    return rcfg.base.moe is not None or rcfg.base.d_ff > 0


def _lru_width(rcfg: ResolvedConfig) -> int:
    return rcfg.base.d_model  # Griffin uses lru_width == d_model for 2b


# ---------------------------------------------------------------------------
# init / spec
# ---------------------------------------------------------------------------

def init_block(rng, rcfg: ResolvedConfig, kind: str, dtype=jnp.bfloat16):
    b = rcfg.base
    d = b.d_model
    k1, k2, k3 = jax.random.split(rng, 3)
    p: Dict[str, Any] = {"norm1": init_rmsnorm(d)}
    if kind in _ATTN_KINDS:
        p["attn"] = init_attention(
            k1, d, rcfg.padded_heads, rcfg.padded_kv_heads, rcfg.head_dim,
            b.qk_norm, dtype)
    elif kind == MLSTM:
        p["mlstm"] = ssm.init_mlstm(k1, d, b.num_heads, dtype)
    elif kind == SLSTM:
        p["slstm"] = ssm.init_slstm(k1, d, b.num_heads, dtype)
    elif kind == RGLRU:
        p["rglru"] = ssm.init_rglru(k1, d, _lru_width(rcfg), dtype)
    else:
        raise ValueError(kind)
    if _has_ffn(rcfg):
        p["norm2"] = init_rmsnorm(d)
        if b.moe is not None:
            p["moe"] = init_moe(k2, d, b.d_ff, b.moe.num_experts, dtype)
        else:
            p["mlp"] = init_mlp(k2, d, b.d_ff, dtype)
    return p


def spec_block(rcfg: ResolvedConfig, kind: str):
    b = rcfg.base
    kv_sharded = rcfg.padded_kv_heads >= rcfg.tp
    s: Dict[str, Any] = {"norm1": spec_rmsnorm()}
    if kind in _ATTN_KINDS:
        s["attn"] = spec_attention(kv_sharded, b.qk_norm)
    elif kind == MLSTM:
        s["mlstm"] = ssm.spec_mlstm()
    elif kind == SLSTM:
        s["slstm"] = ssm.spec_slstm()
    elif kind == RGLRU:
        s["rglru"] = ssm.spec_rglru()
    if _has_ffn(rcfg):
        s["norm2"] = spec_rmsnorm()
        if b.moe is not None:
            strategy = b.moe.strategy
            s["moe"] = spec_moe(strategy)
        else:
            s["mlp"] = spec_mlp()
    return s


# ---------------------------------------------------------------------------
# decode/serve state
# ---------------------------------------------------------------------------

def _attn_alloc(rcfg: ResolvedConfig, kind: str, s_alloc: int) -> int:
    if kind == ATTN_LOCAL:
        return min(rcfg.base.sliding_window, s_alloc)
    return s_alloc


def init_block_state(rcfg: ResolvedConfig, kind: str, batch: int,
                     s_alloc: int, dtype=jnp.bfloat16, kv_dtype=None):
    b = rcfg.base
    if kind in _ATTN_KINDS:
        # kv_dtype compresses ATTENTION caches only (the serving arena's
        # storage dtype); recurrent SSM states keep the compute dtype
        return init_kv_cache(
            batch, _attn_alloc(rcfg, kind, s_alloc),
            rcfg.padded_kv_heads, rcfg.head_dim, kv_dtype or dtype)
    if kind == MLSTM:
        return ssm.init_mlstm_state(batch, b.num_heads, b.d_model // b.num_heads)
    if kind == SLSTM:
        return ssm.init_slstm_state(batch, b.d_model)
    if kind == RGLRU:
        return ssm.init_rglru_state(batch, _lru_width(rcfg))
    raise ValueError(kind)


def block_state_shape(rcfg: ResolvedConfig, kind: str, batch: int,
                      s_alloc: int, dtype=jnp.bfloat16, kv_dtype=None):
    b = rcfg.base
    if kind in _ATTN_KINDS:
        return kv_cache_shape(
            batch, _attn_alloc(rcfg, kind, s_alloc),
            rcfg.padded_kv_heads, rcfg.head_dim, kv_dtype or dtype)
    if kind == MLSTM:
        return ssm.mlstm_state_shape(batch, b.num_heads, b.d_model // b.num_heads)
    if kind == SLSTM:
        return ssm.slstm_state_shape(batch, b.d_model)
    if kind == RGLRU:
        return ssm.rglru_state_shape(batch, _lru_width(rcfg))
    raise ValueError(kind)


def spec_block_state(rcfg: ResolvedConfig, kind: str, *, batch_sharded: bool,
                     seq_sharded: bool):
    """Logical spec for a block's state.

    ``batch_sharded``: batch dim over dp (requires batch % dp == 0).
    ``seq_sharded``: KV sequence dim over data (long-context SP-KV; only
    full-attention caches — ring caches and recurrent states stay local).
    """
    kv_sharded = rcfg.padded_kv_heads >= rcfg.tp
    dp = "dp" if batch_sharded else None
    if kind in _ATTN_KINDS:
        sp = "sp" if (seq_sharded and kind != ATTN_LOCAL) else None
        kv = "tp" if kv_sharded else None
        return {"k": (dp, sp, kv, None), "v": (dp, sp, kv, None)}
    if kind == MLSTM:
        s = ssm.spec_mlstm_state()
    elif kind == SLSTM:
        s = ssm.spec_slstm_state()
    elif kind == RGLRU:
        s = ssm.spec_rglru_state()
    else:
        raise ValueError(kind)
    if not batch_sharded:
        s = jax.tree.map(
            lambda t: tuple(None if a == "dp" else a for a in t), s,
            is_leaf=lambda x: isinstance(x, tuple))
    return s


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------

def block_apply(
    p: Dict[str, Any],
    x: jnp.ndarray,                    # [B, S, D]
    *,
    kind: str,
    rcfg: ResolvedConfig,
    rt: Runtime,
    mode: str,                         # train | prefill | extend | decode
    state: Optional[Any] = None,
    cache_len: Optional[jnp.ndarray] = None,
    q_offset: int = 0,
    kv_len: Optional[jnp.ndarray] = None,      # [B] true length, mode=extend
    slots: Optional[jnp.ndarray] = None,       # [B] arena rows (paged serving)
    block_tables: Optional[jnp.ndarray] = None,  # [B, nblocks] rows per cache
                                               # block (prefix sharing)
    positions: Optional[jnp.ndarray] = None,
    positions3: Optional[jnp.ndarray] = None,
    dp_spec=None,
) -> Tuple[jnp.ndarray, Optional[Any], jnp.ndarray]:
    """Returns (y, new_state, moe_aux_loss)."""
    b = rcfg.base
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm_apply(p["norm1"], x, b.norm_eps)

    if kind in _ATTN_KINDS:
        attn_mode = {"train": "full", "prefill": "full",
                     "extend": "extend", "decode": "decode"}[mode]
        window = b.sliding_window if kind == ATTN_LOCAL else None
        assert slots is None or (kind == ATTN_FULL and window is None), \
            "paged serving (slots) supports full-attention blocks only"
        mix, new_state = attention_apply(
            p["attn"], h,
            rt=rt,
            mode=attn_mode,
            causal=(kind != ENC_ATTN),
            window=window,
            positions=positions,
            positions3=positions3,
            mrope_sections=b.mrope_sections,
            cache=state,
            cache_len=cache_len,
            q_offset=q_offset,
            kv_len=kv_len,
            slots=slots,
            block_tables=block_tables,
            want_cache=(mode != "train"),
            qk_norm=b.qk_norm,
            theta=b.rope_theta,
            norm_eps=b.norm_eps,
        )
    elif kind == MLSTM:
        assert slots is None, \
            "paged serving (slots) supports attention-state models only"
        mix, new_state = ssm.mlstm_apply(
            p["mlstm"], h, state=state,
            mode=("step" if mode == "decode" else "full"),
            heads=b.num_heads)
    elif kind == SLSTM:
        assert slots is None, \
            "paged serving (slots) supports attention-state models only"
        mix, new_state = ssm.slstm_apply(
            p["slstm"], h, state=state, heads=b.num_heads)
    elif kind == RGLRU:
        assert slots is None, \
            "paged serving (slots) supports attention-state models only"
        mix, new_state = ssm.rglru_apply(
            p["rglru"], h, state=state,
            mode=("step" if mode == "decode" else "full"))
    else:
        raise ValueError(kind)

    x = x + mix
    if mode == "train":
        new_state = None

    if _has_ffn(rcfg):
        h2 = rmsnorm_apply(p["norm2"], x, b.norm_eps)
        if b.moe is not None:
            strategy = rt.moe_strategy or b.moe.strategy
            y, aux = moe_apply(
                p["moe"], h2, top_k=b.moe.top_k,
                capacity_factor=b.moe.capacity_factor,
                strategy=strategy, act=b.act,
                mesh=rt.mesh, dp_spec=dp_spec)
        else:
            y = mlp_apply(p["mlp"], h2, b.act)
        x = x + y
    return x, new_state, aux
