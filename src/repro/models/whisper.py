"""Whisper-base: encoder-decoder with cross-attention.

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings [B, S_enc, D].  The transformer
backbone is real: 6 bidirectional encoder layers; 6 decoder layers of
(causal self-attn, cross-attn over encoder output, GELU MLP), LayerNorms,
sinusoidal positions (whisper's learned decoder table is swapped for
sinusoids so the assigned 32k-decode shape cell is well-defined at any
length), tied LM head.

Serving states carry per-decoder-layer self-attn KV caches plus the
cross-attn K/V computed ONCE from the encoder output at prefill — decode
steps never touch the encoder again.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..config import ResolvedConfig
from .attention import (attention_apply, init_attention, init_kv_cache,
                        kv_cache_shape, spec_attention, spec_kv_cache)
from .layers import (embed_apply, init_embed, init_layernorm, init_mlp2,
                     layernorm_apply, lm_head_apply, mlp2_apply, spec_embed,
                     spec_layernorm, spec_mlp2, sinusoidal_positions)
from .runtime import Runtime


def _init_enc_layer(rng, rcfg, dtype):
    b = rcfg.base
    k1, k2 = jax.random.split(rng)
    return {
        "norm1": init_layernorm(b.d_model),
        "attn": init_attention(k1, b.d_model, rcfg.padded_heads,
                               rcfg.padded_kv_heads, rcfg.head_dim, False,
                               dtype),
        "norm2": init_layernorm(b.d_model),
        "mlp": init_mlp2(k2, b.d_model, b.d_ff, dtype),
    }


def _init_dec_layer(rng, rcfg, dtype):
    b = rcfg.base
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "norm1": init_layernorm(b.d_model),
        "self_attn": init_attention(k1, b.d_model, rcfg.padded_heads,
                                    rcfg.padded_kv_heads, rcfg.head_dim,
                                    False, dtype),
        "norm2": init_layernorm(b.d_model),
        "cross_attn": init_attention(k2, b.d_model, rcfg.padded_heads,
                                     rcfg.padded_heads, rcfg.head_dim,
                                     False, dtype),
        "norm3": init_layernorm(b.d_model),
        "mlp": init_mlp2(k3, b.d_model, b.d_ff, dtype),
    }


def _spec_enc_layer(rcfg):
    kv_sharded = rcfg.padded_kv_heads >= rcfg.tp
    return {
        "norm1": spec_layernorm(),
        "attn": spec_attention(kv_sharded, False),
        "norm2": spec_layernorm(),
        "mlp": spec_mlp2(),
    }


def _spec_dec_layer(rcfg):
    kv_sharded = rcfg.padded_kv_heads >= rcfg.tp
    return {
        "norm1": spec_layernorm(),
        "self_attn": spec_attention(kv_sharded, False),
        "norm2": spec_layernorm(),
        "cross_attn": spec_attention(True, False),
        "norm3": spec_layernorm(),
        "mlp": spec_mlp2(),
    }


@dataclass(frozen=True)
class WhisperModel:
    rcfg: ResolvedConfig
    rt: Runtime

    @property
    def dtype(self):
        return jnp.bfloat16 if self.rcfg.base.dtype == "bfloat16" else jnp.float32

    @property
    def n_enc(self) -> int:
        return self.rcfg.base.encoder_layers or 0

    @property
    def n_dec(self) -> int:
        return self.rcfg.base.num_layers

    # ---------------------------------------------------------------- params
    def init(self, rng):
        b = self.rcfg.base
        k_emb, k_enc, k_dec, k_in = jax.random.split(rng, 4)
        return {
            "embed": init_embed(k_emb, self.rcfg.padded_vocab, b.d_model,
                                self.dtype),
            "frame_proj": (jax.random.normal(k_in, (b.d_model, b.d_model),
                                             jnp.float32) * 0.02).astype(self.dtype),
            "enc": tuple(_init_enc_layer(jax.random.fold_in(k_enc, i),
                                         self.rcfg, self.dtype)
                         for i in range(self.n_enc)),
            "enc_norm": init_layernorm(b.d_model),
            "dec": tuple(_init_dec_layer(jax.random.fold_in(k_dec, i),
                                         self.rcfg, self.dtype)
                         for i in range(self.n_dec)),
            "dec_norm": init_layernorm(b.d_model),
        }

    def param_specs(self):
        return {
            "embed": spec_embed(),
            "frame_proj": (None, "tp"),
            "enc": tuple(_spec_enc_layer(self.rcfg) for _ in range(self.n_enc)),
            "enc_norm": spec_layernorm(),
            "dec": tuple(_spec_dec_layer(self.rcfg) for _ in range(self.n_dec)),
            "dec_norm": spec_layernorm(),
        }

    # ---------------------------------------------------------------- states
    def state_shapes(self, batch: int, s_alloc: int):
        b = self.rcfg.base
        self_kv = tuple(
            kv_cache_shape(batch, s_alloc, self.rcfg.padded_kv_heads,
                           self.rcfg.head_dim, self.dtype)
            for _ in range(self.n_dec))
        cross = tuple(
            {"k": jax.ShapeDtypeStruct(
                (batch, b.encoder_seq_len, self.rcfg.padded_heads,
                 self.rcfg.head_dim), self.dtype),
             "v": jax.ShapeDtypeStruct(
                (batch, b.encoder_seq_len, self.rcfg.padded_heads,
                 self.rcfg.head_dim), self.dtype)}
            for _ in range(self.n_dec))
        return {"self": self_kv, "cross": cross}

    def state_specs(self, *, batch_sharded: bool, seq_sharded: bool = False):
        dp = "dp" if batch_sharded else None
        kv_sharded = self.rcfg.padded_kv_heads >= self.rcfg.tp
        kv = "tp" if kv_sharded else None
        self_kv = tuple({"k": (dp, None, kv, None), "v": (dp, None, kv, None)}
                        for _ in range(self.n_dec))
        cross = tuple({"k": (dp, None, "tp", None), "v": (dp, None, "tp", None)}
                      for _ in range(self.n_dec))
        return {"self": self_kv, "cross": cross}

    # ------------------------------------------------------------------ core
    def encode(self, params, frame_emb: jnp.ndarray) -> jnp.ndarray:
        """frame_emb [B, S_enc, D] (stub frontend output) -> enc states."""
        b = self.rcfg.base
        B, S, D = frame_emb.shape
        x = frame_emb.astype(self.dtype) @ params["frame_proj"]
        x = x + sinusoidal_positions(jnp.arange(S), D)[None].astype(self.dtype)
        for lp in params["enc"]:
            h = layernorm_apply(lp["norm1"], x)
            mix, _ = attention_apply(
                lp["attn"], h, rt=self.rt, mode="full", causal=False,
                positions=None, theta=b.rope_theta, use_rope=False)
            x = x + mix
            h = layernorm_apply(lp["norm2"], x)
            x = x + mlp2_apply(lp["mlp"], h, "gelu")
        return layernorm_apply(params["enc_norm"], x)

    def _cross_kv(self, params, enc_out):
        """Precompute cross-attention K/V per decoder layer."""
        out = []
        for lp in params["dec"]:
            p = lp["cross_attn"]
            k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
            v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
            out.append({"k": k, "v": v})
        return tuple(out)

    def _dec_layer(self, lp, x, *, mode, self_cache, cross_kv, positions,
                   cache_len, q_offset):
        b = self.rcfg.base
        h = layernorm_apply(lp["norm1"], x)
        mix, new_cache = attention_apply(
            lp["self_attn"], h, rt=self.rt, mode=mode, causal=True,
            positions=positions, cache=self_cache, cache_len=cache_len,
            q_offset=q_offset, want_cache=(mode != "full"),
            theta=b.rope_theta, use_rope=False)
        x = x + mix
        h = layernorm_apply(lp["norm2"], x)
        mix, _ = attention_apply(
            lp["cross_attn"], h, rt=self.rt,
            kv_ctx=(cross_kv["k"], cross_kv["v"]))
        x = x + mix
        h = layernorm_apply(lp["norm3"], x)
        x = x + mlp2_apply(lp["mlp"], h, "gelu")
        return x, new_cache

    # ------------------------------------------------------------ entry pts
    def forward(self, params, batch: Dict[str, jnp.ndarray]):
        """Teacher-forced training forward -> (logits [B, S, V], aux=0)."""
        enc_out = self.encode(params, batch["frame_emb"])
        cross = self._cross_kv(params, enc_out)
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = embed_apply(params["embed"], tokens).astype(self.dtype)
        x = x + sinusoidal_positions(jnp.arange(S),
                                     x.shape[-1])[None].astype(self.dtype)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        for li, lp in enumerate(params["dec"]):
            x, _ = self._dec_layer(
                lp, x, mode="full", self_cache=None, cross_kv=cross[li],
                positions=positions, cache_len=None, q_offset=0)
        x = layernorm_apply(params["dec_norm"], x)
        logits = lm_head_apply(params["embed"], x)
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch)
        labels = batch["labels"]
        V = logits.shape[-1]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        onehot = jax.nn.one_hot(labels, V, dtype=jnp.float32)
        tok_ll = jnp.sum(onehot * logp, axis=-1)
        mask = batch.get("loss_mask", jnp.ones_like(tok_ll))
        return -jnp.sum(tok_ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    def prefill(self, params, batch: Dict[str, jnp.ndarray], *,
                s_alloc: Optional[int] = None):
        """Encode + teacher-force the prompt -> (last logits, states)."""
        enc_out = self.encode(params, batch["frame_emb"])
        cross = self._cross_kv(params, enc_out)
        tokens = batch["tokens"]
        B, S = tokens.shape
        alloc = s_alloc or S
        x = embed_apply(params["embed"], tokens).astype(self.dtype)
        x = x + sinusoidal_positions(jnp.arange(S),
                                     x.shape[-1])[None].astype(self.dtype)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        new_self = []
        for li, lp in enumerate(params["dec"]):
            cache = init_kv_cache(B, alloc, self.rcfg.padded_kv_heads,
                                  self.rcfg.head_dim, self.dtype)
            x, nc = self._dec_layer(
                lp, x, mode="extend", self_cache=cache, cross_kv=cross[li],
                positions=positions, cache_len=jnp.zeros((B,), jnp.int32),
                q_offset=0)
            new_self.append(nc)
        x = layernorm_apply(params["dec_norm"], x[:, -1:])
        logits = lm_head_apply(params["embed"], x)[:, 0]
        return logits, {"self": tuple(new_self), "cross": cross}

    def decode_step(self, params, tokens: jnp.ndarray, states,
                    pos: jnp.ndarray):
        """tokens [B], pos [B] -> (logits [B, V], states)."""
        B = tokens.shape[0]
        x = embed_apply(params["embed"], tokens[:, None]).astype(self.dtype)
        d = x.shape[-1]
        x = x + sinusoidal_positions(pos[:, None], d).astype(self.dtype)
        new_self = []
        for li, lp in enumerate(params["dec"]):
            x, nc = self._dec_layer(
                lp, x, mode="decode", self_cache=states["self"][li],
                cross_kv=states["cross"][li], positions=pos[:, None],
                cache_len=pos, q_offset=0)
            new_self.append(nc)
        x = layernorm_apply(params["dec_norm"], x)
        logits = lm_head_apply(params["embed"], x)[:, 0]
        return logits, {"self": tuple(new_self), "cross": states["cross"]}
