"""GQA attention block with full / sliding-window variants and KV caches.

Modes
-----
``full``     causal (or bidirectional) self-attention over the whole input;
             optionally emits a KV cache ("prefill").
``extend``   chunked prefill: queries are a suffix at static ``q_offset``;
             cached KV for ``[0, q_offset)`` is reused (the cascade
             fraction-extension primitive).
``decode``   one new token per sequence against the cache.

Caches are dicts ``{"k": [B, S_alloc, KV, Dh], "v": ...}``; keys are stored
*post-RoPE* so cache entries are position-final.  Sliding-window layers use
ring caches (``S_alloc = window``, slot = pos % window) — valid because
softmax attention is permutation-invariant over the key set once positions
are baked into the keys.

Paged serving: ``extend``/``decode`` also accept ``slots`` [B], in which
case ``cache`` is a persistent slot ARENA ``{"k": [N_rows, S_alloc, KV,
Dh], ...}`` shared by many documents — row ``slots[b]`` belongs to batch
row ``b`` (the last arena row is the serving scratch/padding sentinel).
Chunk and decode KV are scattered into the addressed rows in place and
attention reads the arena through the paged kernels
(``ops.attention_paged`` / ``ops.arena_decode_attention``) — no [B, S]
gather copy.  Paged mode supports full causal attention only (no sliding
window / cross-attention); ``models.model.LM.supports_paged_kv`` gates it.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from .layers import apply_rope, apply_mrope, init_dense, init_rmsnorm, rmsnorm_apply
from .runtime import Runtime


def init_attention(rng, d: int, h: int, kv: int, dh: int, qk_norm: bool,
                   dtype=jnp.bfloat16) -> Dict[str, Any]:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    p = {
        "wq": init_dense(k1, (d, h * dh), dtype).reshape(d, h, dh),
        "wk": init_dense(k2, (d, kv * dh), dtype).reshape(d, kv, dh),
        "wv": init_dense(k3, (d, kv * dh), dtype).reshape(d, kv, dh),
        "wo": init_dense(k4, (h * dh, d), dtype).reshape(h, dh, d),
    }
    if qk_norm:
        p["q_norm"] = init_rmsnorm(dh)
        p["k_norm"] = init_rmsnorm(dh)
    return p


def spec_attention(kv_sharded: bool, qk_norm: bool) -> Dict[str, Any]:
    kv_spec = (None, "tp", None) if kv_sharded else (None, None, None)
    s = {
        "wq": (None, "tp", None),
        "wk": kv_spec,
        "wv": kv_spec,
        "wo": ("tp", None, None),
    }
    if qk_norm:
        s["q_norm"] = {"scale": (None,)}
        s["k_norm"] = {"scale": (None,)}
    return s


def init_kv_cache(batch: int, s_alloc: int, kv: int, dh: int, dtype=jnp.bfloat16):
    return {
        "k": jnp.zeros((batch, s_alloc, kv, dh), dtype),
        "v": jnp.zeros((batch, s_alloc, kv, dh), dtype),
    }


def kv_cache_shape(batch: int, s_alloc: int, kv: int, dh: int, dtype=jnp.bfloat16):
    return {
        "k": jax.ShapeDtypeStruct((batch, s_alloc, kv, dh), dtype),
        "v": jax.ShapeDtypeStruct((batch, s_alloc, kv, dh), dtype),
    }


def spec_kv_cache(kv_sharded: bool, sp: bool):
    """Cache logical spec: batch over dp; optionally sequence over sp(data)."""
    seq = "sp" if sp else None
    kv = "tp" if kv_sharded else None
    return {"k": ("dp", seq, kv, None), "v": ("dp", seq, kv, None)}


def _project_qkv(p, x, positions, *, theta, qk_norm, mrope_sections=None,
                 positions3=None, norm_eps=1e-6):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if qk_norm:
        q = rmsnorm_apply(p["q_norm"], q, norm_eps)
        k = rmsnorm_apply(p["k_norm"], k, norm_eps)
    if mrope_sections is not None:
        q = apply_mrope(q, positions3, theta, mrope_sections)
        k = apply_mrope(k, positions3, theta, mrope_sections)
    elif positions is not None:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def attention_apply(
    p: Dict[str, Any],
    x: jnp.ndarray,                  # [B, S, D]
    *,
    rt: Runtime,
    mode: str = "full",              # full | extend | decode
    causal: bool = True,
    window: Optional[int] = None,
    positions: Optional[jnp.ndarray] = None,   # [B, S] absolute positions
    positions3: Optional[jnp.ndarray] = None,  # [B, S, 3] for M-RoPE
    mrope_sections=None,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    cache_len: Optional[jnp.ndarray] = None,   # [B] int32 valid cache entries
    q_offset: int = 0,               # static, mode=extend
    kv_len: Optional[jnp.ndarray] = None,      # [B] true (unpadded) length
                                               # incl. this chunk, mode=extend
    slots: Optional[jnp.ndarray] = None,       # [B] arena rows (paged serving)
    block_tables: Optional[jnp.ndarray] = None,  # [B, S_alloc // block] rows
                                               # per cache block (prefix
                                               # sharing); reads only — all
                                               # writes go through ``slots``
    want_cache: bool = False,
    qk_norm: bool = False,
    theta: float = 10_000.0,
    norm_eps: float = 1e-6,
    use_rope: bool = True,           # whisper uses absolute sinusoids instead
    kv_ctx: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # cross-attn K,V
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    B, S, D = x.shape
    dh = p["wq"].shape[-1]
    sm_scale = 1.0 / math.sqrt(dh)

    if kv_ctx is not None:
        # cross attention (whisper decoder): kv precomputed from encoder
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        k, v = kv_ctx
        out = ops.attention(q, k, v, causal=False, impl=rt.attn_impl,
                            sm_scale=sm_scale, block_q=rt.block_q,
                            block_kv=rt.block_kv)
        out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
        return out, None

    if positions is None and positions3 is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if mrope_sections is not None and positions3 is None:
        # text-only input on an M-RoPE arch: t = h = w = position
        positions3 = jnp.broadcast_to(
            positions[..., None], positions.shape + (3,)).astype(jnp.int32)

    q, k, v = _project_qkv(
        p, x, positions if use_rope else None, theta=theta, qk_norm=qk_norm,
        mrope_sections=mrope_sections if use_rope else None,
        positions3=positions3, norm_eps=norm_eps,
    )

    new_cache = None

    if mode == "full":
        out = ops.attention(
            q, k, v, causal=causal, window=window, impl=rt.attn_impl,
            sm_scale=sm_scale, block_q=rt.block_q, block_kv=rt.block_kv,
        )
        if want_cache:
            if window is not None and window > 0:
                s_keep = min(S, window)
                # ring layout: absolute position pos -> slot pos % window
                kk = k[:, -s_keep:]
                vv = v[:, -s_keep:]
                pos_tail = positions[:, -s_keep:]
                ring = pos_tail % window                        # [B, s_keep]
                ck = jnp.zeros((B, window) + k.shape[2:], k.dtype)
                cv = jnp.zeros_like(ck)
                bidx = jnp.arange(B)[:, None]
                ck = ck.at[bidx, ring].set(kk)
                cv = cv.at[bidx, ring].set(vv)
                new_cache = {"k": ck, "v": cv}
            else:
                new_cache = {"k": k, "v": v}
    elif mode == "extend":
        assert cache is not None
        if slots is not None:
            # paged extend: ``cache`` is the slot arena [N_rows, S, KV, Dh];
            # scatter the chunk's KV into the addressed rows, then attend
            # in place through the paged kernel (no [B, S] gather)
            assert window in (None, 0), \
                "paged extend supports full attention only"
            kv_valid = min(q_offset + S, cache["k"].shape[1])
            # the arena may store KV compressed (bf16 for f32 models):
            # quantize on the scatter; the kernels upcast to f32 at read
            ck = cache["k"].at[slots, q_offset:q_offset + S].set(
                k.astype(cache["k"].dtype))
            cv = cache["v"].at[slots, q_offset:q_offset + S].set(
                v.astype(cache["v"].dtype))
            out = ops.attention_paged(
                q, ck, cv, slots, kv_valid=kv_valid,
                block_tables=block_tables, causal=causal,
                q_offset=q_offset, kv_len=kv_len, impl=rt.attn_impl,
                sm_scale=sm_scale, block_q=rt.block_q, block_kv=rt.block_kv,
            )
            if want_cache:
                new_cache = {"k": ck, "v": cv}
        elif window is not None and window > 0 and q_offset == 0:
            # fresh prefill routed through extend (cache preallocated but
            # empty): use the blocked kernel directly — the ragged
            # ring-merge path below would materialize [S, W+S] scores
            # (measured 17+ GB/layer/chip on gemma3 prefill_32k; see
            # EXPERIMENTS.md §Perf iteration 1).
            out = ops.attention(
                q, k, v, causal=causal, window=window, kv_len=kv_len,
                impl=rt.attn_impl, sm_scale=sm_scale, block_q=rt.block_q,
                block_kv=rt.block_kv,
            )
            if want_cache:
                Wn = cache["k"].shape[1]
                s_keep = min(S, Wn)
                kk = k[:, -s_keep:]
                vv = v[:, -s_keep:]
                pos_tail = positions[:, -s_keep:]
                ring = pos_tail % Wn
                bidx = jnp.arange(B)[:, None]
                ck = cache["k"].at[bidx, ring].set(kk)
                cv = cache["v"].at[bidx, ring].set(vv)
                new_cache = {"k": ck, "v": cv}
        elif window is not None and window > 0:
            # small-window extend: attend over ring cache + new chunk with
            # exact per-key absolute positions (naive masked path; cheap at
            # window scale).  Positions of ring slots are recoverable from
            # slot index and current absolute offset.
            Wn = cache["k"].shape[1]
            slot = jnp.arange(Wn)[None, :]                       # [1, W]
            # exact slot->pos map: pos = largest p < q_offset with p% W == slot
            kpos = slot + ((q_offset - 1 - slot) // Wn) * Wn
            k_all = jnp.concatenate([cache["k"], k], axis=1)
            v_all = jnp.concatenate([cache["v"], v], axis=1)
            kpos_all = jnp.concatenate(
                [jnp.broadcast_to(kpos, (B, Wn)),
                 positions.astype(jnp.int32)], axis=1)           # [B, W+S]
            qpos = positions[..., None]                          # [B,S,1]
            valid = (kpos_all[:, None, :] <= qpos) & \
                    (kpos_all[:, None, :] > qpos - window) & \
                    (kpos_all[:, None, :] >= 0)
            if kv_len is not None:
                valid &= kpos_all[:, None, :] < kv_len[:, None, None]
            g = q.shape[2] // k_all.shape[2]
            kf = jnp.repeat(k_all.astype(jnp.float32), g, axis=2)
            vf = jnp.repeat(v_all.astype(jnp.float32), g, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * sm_scale, kf)
            s = jnp.where(valid[:, None], s, -1e30)
            pr = jax.nn.softmax(s, axis=-1)
            out = jnp.einsum("bhqk,bkhd->bqhd", pr, vf).astype(x.dtype)
            if want_cache:
                ring = positions % window
                bidx = jnp.arange(B)[:, None]
                ck = cache["k"].at[bidx, ring].set(k)
                cv = cache["v"].at[bidx, ring].set(v)
                new_cache = {"k": ck, "v": cv}
        else:
            # full-attention extend: write new kv at [q_offset, q_offset+S)
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), q_offset, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), q_offset, 1)
            kv_valid = q_offset + S
            out = ops.attention(
                q, ck[:, :kv_valid] if kv_valid < ck.shape[1] else ck,
                cv[:, :kv_valid] if kv_valid < cv.shape[1] else cv,
                causal=causal, q_offset=q_offset, kv_len=kv_len,
                impl=rt.attn_impl, sm_scale=sm_scale, block_q=rt.block_q,
                block_kv=rt.block_kv,
            )
            if want_cache:
                new_cache = {"k": ck, "v": cv}
    elif mode == "decode":
        assert cache is not None and cache_len is not None and S == 1
        # decode masks by cache_len (valid cache entries); a per-row
        # kv_len override is an extend-only contract — reject it loudly
        # rather than silently ignoring it
        assert kv_len is None, "kv_len is mode='extend' only; decode " \
            "masks by cache_len"
        if slots is not None:
            # paged decode: write the token's KV at (slots[b], cache_len[b])
            # and read the arena in place — slot ids resolve inside the
            # kernel (scalar-prefetch SMEM), eliminating the gather copy
            assert window in (None, 0), \
                "paged decode supports full attention only"
            ck = cache["k"].at[slots, cache_len].set(
                k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[slots, cache_len].set(
                v[:, 0].astype(cache["v"].dtype))
            out1 = ops.arena_decode_attention(
                q[:, 0], ck, cv, slots, cache_len + 1,
                block_tables=block_tables, sm_scale=sm_scale,
                impl=rt.attn_impl, block_kv=rt.block_kv,
            )
        else:
            if window is not None and window > 0:
                Wn = cache["k"].shape[1]
                ring = (positions[:, 0] % Wn)
                bidx = jnp.arange(B)
                ck = cache["k"].at[bidx, ring].set(k[:, 0])
                cv = cache["v"].at[bidx, ring].set(v[:, 0])
                kv_valid = jnp.minimum(cache_len + 1, Wn)
            else:
                bidx = jnp.arange(B)
                ck = cache["k"].at[bidx, cache_len].set(k[:, 0])
                cv = cache["v"].at[bidx, cache_len].set(v[:, 0])
                kv_valid = cache_len + 1
            if rt.sp_decode and rt.mesh is not None and window in (None, 0):
                from ..distributed.collectives import sp_decode_attention
                out1 = sp_decode_attention(
                    q[:, 0], ck, cv, kv_valid, mesh=rt.mesh,
                    sm_scale=sm_scale)
            else:
                out1 = ops.decode_attention(
                    q[:, 0], ck, cv, kv_valid, sm_scale=sm_scale,
                    impl=rt.attn_impl, block_kv=rt.block_kv,
                )
        out = out1[:, None]
        new_cache = {"k": ck, "v": cv}
    else:
        raise ValueError(mode)

    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, new_cache
