"""Primitive layers: norms, rotary embeddings (incl. M-RoPE), MLPs.

All modules are functional triples ``init_*(rng, ...) -> params``,
``spec_*(...) -> logical-axis pytree``, ``*_apply(params, x, ...) -> y``.
Logical axes: "tp" (model), "ep" (experts/data), None (replicated) — see
``repro.distributed.sharding``.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _dense_init(rng, shape, in_axis_size, dtype):
    scale = 1.0 / math.sqrt(max(in_axis_size, 1))
    return (jax.random.uniform(rng, shape, jnp.float32, -1.0, 1.0) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def spec_rmsnorm():
    return {"scale": (None,)}


def rmsnorm_apply(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def spec_layernorm():
    return {"scale": (None,), "bias": (None,)}


def layernorm_apply(params, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape [head_dim//2]."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Standard RoPE.  x: [..., S, H, Dh]; positions: [..., S] (int)."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                       # [dh/2]
    ang = positions[..., :, None].astype(jnp.float32) * inv  # [..., S, dh/2]
    cos = jnp.cos(ang)[..., :, None, :]               # [..., S, 1, dh/2]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray,
    positions3: jnp.ndarray,
    theta: float,
    sections: Tuple[int, int, int],
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.

    x: [B, S, H, Dh]; positions3: [B, S, 3] — (t, h, w) position ids.
    ``sections`` gives the number of *frequency pairs* per (t,h,w) section;
    sum(sections) == Dh // 2.
    """
    dh = x.shape[-1]
    half = dh // 2
    assert sum(sections) == half, (sections, dh)
    inv = rope_freqs(dh, theta)                       # [half]
    # section id per frequency index
    sec_id = jnp.concatenate([
        jnp.full((sections[0],), 0, jnp.int32),
        jnp.full((sections[1],), 1, jnp.int32),
        jnp.full((sections[2],), 2, jnp.int32),
    ])                                                # [half]
    # pick the position channel per frequency: [B, S, half]
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(sec_id[None, None, :], positions3.shape[:2] + (half,)),
        axis=-1,
    )
    ang = pos * inv[None, None, :]                    # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / plain)
# ---------------------------------------------------------------------------

ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
}


def init_mlp(rng, d: int, f: int, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "w1": _dense_init(k1, (d, f), d, dtype),
        "w3": _dense_init(k2, (d, f), d, dtype),
        "w2": _dense_init(k3, (f, d), f, dtype),
    }


def spec_mlp():
    return {"w1": (None, "tp"), "w3": (None, "tp"), "w2": ("tp", None)}


def mlp_apply(params, x, act: str = "silu"):
    a = ACTS[act]
    h = a(x @ params["w1"]) * (x @ params["w3"])
    return h @ params["w2"]


def init_mlp2(rng, d: int, f: int, dtype=jnp.bfloat16):
    """Plain 2-layer MLP (whisper-style GELU, no gating)."""
    k1, k2 = jax.random.split(rng)
    return {
        "w1": _dense_init(k1, (d, f), d, dtype),
        "b1": jnp.zeros((f,), jnp.float32),
        "w2": _dense_init(k2, (f, d), f, dtype),
        "b2": jnp.zeros((d,), jnp.float32),
    }


def spec_mlp2():
    return {"w1": (None, "tp"), "b1": ("tp",), "w2": ("tp", None), "b2": (None,)}


def mlp2_apply(params, x, act: str = "gelu"):
    a = ACTS[act]
    h = a(x @ params["w1"] + params["b1"].astype(x.dtype))
    return h @ params["w2"] + params["b2"].astype(x.dtype)


def sinusoidal_positions(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    """Sinusoidal position encodings. positions: [...,] -> [..., d]."""
    half = d // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                    / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def init_embed(rng, vocab: int, d: int, dtype=jnp.bfloat16):
    return {"table": (jax.random.normal(rng, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def spec_embed():
    # vocab-parallel embedding: rows sharded over model axis
    return {"table": ("tp", None)}


def embed_apply(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def lm_head_apply(params, x, softcap: Optional[float] = None):
    """Tied head: logits = x @ table.T with f32 ACCUMULATION.

    The table stays in its storage dtype — casting it to f32 materialized
    a full converted+transposed copy of the vocab shard every step
    (measured +0.7 GB/chip/decode-step on gemma3; §Perf iteration 3).
    """
    logits = jnp.einsum("...d,vd->...v", x, params["table"],
                        preferred_element_type=jnp.float32)
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


def init_dense(rng, shape, dtype=jnp.bfloat16):
    return _dense_init(rng, shape, shape[0], dtype)
