"""Runtime knobs shared across the model zoo (impl selection, meshes)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class Runtime:
    """Execution-environment knobs, orthogonal to architecture configs.

    attn_impl: "xla" | "pallas" | "pallas_interpret" | "naive"
        The dry-run (CPU AOT) uses "xla" (Mosaic cannot target CPU);
        TPU deployment uses "pallas"; CPU unit tests use "pallas_interpret"
        or "naive".
    sp_decode: shard the KV sequence dim over the data axis at decode time
        (long-context, batch=1) and combine partial softmaxes.
    sp_activations: Megatron-style sequence sharding of the residual stream
        between blocks (training memory saver).
    """

    attn_impl: str = "xla"
    block_q: int = 512
    block_kv: int = 512
    sp_decode: bool = False
    sp_activations: bool = False
    mesh: Optional[object] = None        # jax Mesh when running distributed
    remat: bool = True                   # checkpoint each superblock in train
    moe_strategy: Optional[str] = None   # override config strategy
    # Unroll the superblock scan into a Python loop.  Used by the dry-run's
    # R=1/R=2 cost-extrapolation compiles (XLA's HloCostAnalysis counts a
    # while-loop body once, so scanned-layer FLOPs must be recovered from
    # unrolled small-depth compiles).
    unroll_layers: bool = False


CPU_TEST = Runtime(attn_impl="naive", remat=False)
CPU_KERNEL_TEST = Runtime(attn_impl="pallas_interpret", block_q=16, block_kv=16, remat=False)
