"""Deterministic offline tokenizers (no external vocab files).

``HashWordTokenizer`` — whitespace words hashed into a fixed vocab; stable
across processes (blake2).  Reserves low ids for specials and class-answer
tokens so the cascade engine can read class confidences off the LM head.

``ByteTokenizer`` — raw UTF-8 bytes + specials; used by tiny training
examples where a 256-way output keeps the model small.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

PAD, BOS, EOS = 0, 1, 2
N_SPECIALS = 8           # pad/bos/eos + up to 5 reserved
CLASS_BASE = 8           # class c answer token = CLASS_BASE + c
MAX_CLASSES = 8


def class_token(c: int) -> int:
    assert 0 <= c < MAX_CLASSES
    return CLASS_BASE + c


@dataclass(frozen=True)
class HashWordTokenizer:
    vocab_size: int = 50_304

    @property
    def first_word_id(self) -> int:
        return CLASS_BASE + MAX_CLASSES

    def _word_id(self, w: str) -> int:
        h = hashlib.blake2b(w.lower().encode(), digest_size=4).digest()
        span = self.vocab_size - self.first_word_id
        return self.first_word_id + int.from_bytes(h, "little") % span

    def encode(self, text: str, *, bos: bool = False) -> List[int]:
        ids = [BOS] if bos else []
        ids += [self._word_id(w) for w in text.split()]
        return ids

    def encode_batch(self, texts: Sequence[str], seq_len: int,
                     *, bos: bool = True) -> np.ndarray:
        out = np.full((len(texts), seq_len), PAD, np.int32)
        for i, t in enumerate(texts):
            ids = self.encode(t, bos=bos)[:seq_len]
            out[i, : len(ids)] = ids
        return out


@dataclass(frozen=True)
class ByteTokenizer:
    vocab_size: int = 256 + N_SPECIALS

    def encode(self, text: str, *, bos: bool = False) -> List[int]:
        ids = [BOS] if bos else []
        ids += [N_SPECIALS + b for b in text.encode("utf-8")]
        return ids

    def decode(self, ids: Sequence[int]) -> str:
        return bytes(i - N_SPECIALS for i in ids
                     if i >= N_SPECIALS).decode("utf-8", "replace")
