"""Sharded, fault-tolerant data pipeline.

Deterministic *redundant shard assignment*: logical data shards are mapped
to hosts by seeded hash; each shard is also assigned R-1 backup hosts, so
when a host dies any survivor can recompute exactly the lost shard's
batches (generation is a pure function of (seed, shard, step)).  This is
the standard trick for input-pipeline fault tolerance without a central
data service.

``SyntheticLMTask`` generates next-token-predictable sequences (repeating
patterns + noise) so tiny training runs show decreasing loss — used by the
train example and integration tests.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np


def _stable_hash(*keys) -> int:
    h = hashlib.blake2b("|".join(map(str, keys)).encode(),
                        digest_size=8).digest()
    return int.from_bytes(h, "little")


@dataclass(frozen=True)
class ShardPlan:
    """Deterministic shard->host map with redundancy R."""
    n_shards: int
    n_hosts: int
    redundancy: int = 2
    seed: int = 0

    def hosts_for(self, shard: int) -> List[int]:
        """Primary + backup hosts for a shard (distinct, seeded)."""
        out = []
        i = 0
        while len(out) < min(self.redundancy, self.n_hosts):
            h = _stable_hash(self.seed, "shard", shard, i) % self.n_hosts
            if h not in out:
                out.append(h)
            i += 1
        return out

    def shards_for_host(self, host: int,
                        dead_hosts: Sequence[int] = ()) -> List[int]:
        """Shards this host must produce, including failover pickups.

        A shard normally served by its primary falls to the first live
        backup when the primary is dead.
        """
        dead = set(dead_hosts)
        out = []
        for s in range(self.n_shards):
            for owner in self.hosts_for(s):
                if owner not in dead:
                    if owner == host:
                        out.append(s)
                    break
        return out


@dataclass
class SyntheticLMTask:
    """Learnable synthetic LM data: periodic token patterns + noise."""
    vocab_size: int
    seq_len: int
    period: int = 8
    noise: float = 0.05

    def batch(self, seed: int, shard: int, step: int,
              batch_size: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(_stable_hash(seed, shard, step))
        base = rng.integers(
            9, self.vocab_size, size=(batch_size, self.period))
        reps = int(np.ceil((self.seq_len + 1) / self.period))
        seq = np.tile(base, (1, reps))[:, : self.seq_len + 1]
        flip = rng.random(seq.shape) < self.noise
        seq = np.where(flip, rng.integers(9, self.vocab_size, seq.shape), seq)
        return {
            "tokens": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32),
        }


@dataclass
class DataPipeline:
    """Per-host iterator over the host's (possibly failed-over) shards."""
    task: SyntheticLMTask
    plan: ShardPlan
    host: int
    batch_per_shard: int
    seed: int = 0
    dead_hosts: tuple = ()
    step: int = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        shards = self.plan.shards_for_host(self.host, self.dead_hosts)
        if not shards:
            raise StopIteration
        parts = [self.task.batch(self.seed, s, self.step,
                                 self.batch_per_shard) for s in shards]
        self.step += 1
        return {k: np.concatenate([p[k] for p in parts]) for k in parts[0]}

    def with_failures(self, dead_hosts: Sequence[int]) -> "DataPipeline":
        """Continue the SAME stream with hosts marked dead (failover)."""
        return DataPipeline(self.task, self.plan, self.host,
                            self.batch_per_shard, self.seed,
                            tuple(dead_hosts), self.step)
