"""Synthetic text corpora with planted relevance structure.

Real-text twin of the score-level simulator: documents are actual line
sequences (so §4 document restructuring runs for real — line splitting,
oracle range labeling, chunking, classifier training, reordering), with a
known ground truth for tests:

  * each document has a class label;
  * a few *relevant* lines carry class-signal keywords;
  * remaining lines are filler drawn from a shared word pool;
  * distractor lines mention signal words of OTHER classes (so a naive
    keyword grep is not enough and the learned classifier has work to do).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

FILLER = ("the quick brown fox jumps over lazy dogs while market conditions "
          "remain stable and committee review proceeds according to standard "
          "schedule with no material findings reported during the interim "
          "period as stakeholders await further guidance on pending matters "
          "from relevant departments and administrative units across regions"
          ).split()

CLASS_SIGNALS = [
    ["overturn", "reversed", "vacated", "remanded"],
    ["affirmed", "upheld", "sustained", "denied"],
    ["merger", "acquisition", "quarterly", "dividend"],
    ["tournament", "playoff", "championship", "score"],
    ["genome", "protein", "clinical", "cohort"],
    ["satellite", "quantum", "processor", "algorithm"],
]

# lines that LOOK substantive but are irrelevant to the operation (they make
# naive keyword retrieval imperfect without creating contradictory labels)
DISTRACTOR_SIGNALS = ["footnote", "docket", "stipulated", "continuance",
                      "exhibits", "transcript", "scheduling", "amended"]


@dataclass
class SyntheticDoc:
    doc_id: int
    lines: List[str]
    label: int
    relevant_lines: List[int]

    @property
    def text(self) -> str:
        return "\n".join(self.lines)

    def reordered(self, order: Sequence[int]) -> "SyntheticDoc":
        inv = list(order)
        return SyntheticDoc(
            self.doc_id, [self.lines[i] for i in inv], self.label,
            [inv.index(r) for r in self.relevant_lines if r in inv])


def _filler_line(rng: np.random.Generator, width: int = 10) -> str:
    return " ".join(rng.choice(FILLER, size=width))


def _signal_line(rng: np.random.Generator, cls: int, width: int = 10) -> str:
    words = list(rng.choice(FILLER, size=width - 2))
    sig = rng.choice(CLASS_SIGNALS[cls], size=2)
    pos = sorted(rng.choice(width - 2, size=2, replace=False))
    for p, s in zip(pos, sig):
        words.insert(int(p), str(s))
    return " ".join(words)


def generate_corpus(
    n_docs: int,
    n_classes: int = 2,
    avg_lines: int = 40,
    n_relevant: int = 3,
    distractor_p: float = 0.05,
    seed: int = 0,
) -> List[SyntheticDoc]:
    assert n_classes <= len(CLASS_SIGNALS)
    rng = np.random.default_rng(seed)
    docs = []
    for i in range(n_docs):
        label = int(rng.integers(0, n_classes))
        n_lines = max(int(rng.normal(avg_lines, avg_lines * 0.25)),
                      n_relevant + 4)
        rel = sorted(rng.choice(n_lines, size=n_relevant, replace=False))
        lines = []
        for li in range(n_lines):
            if li in rel:
                lines.append(_signal_line(rng, label))
            elif rng.random() < distractor_p:
                words = list(rng.choice(FILLER, size=8))
                words.insert(int(rng.integers(8)),
                             str(rng.choice(DISTRACTOR_SIGNALS)))
                lines.append(" ".join(words))
            else:
                lines.append(_filler_line(rng))
        docs.append(SyntheticDoc(i, lines, label, [int(r) for r in rel]))
    return docs


def doc_contains_signal(doc_text: str, cls: int) -> bool:
    t = doc_text.lower()
    return any(s in t for s in CLASS_SIGNALS[cls])
